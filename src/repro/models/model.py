"""Model assembly: params init, sharding rules, train/prefill/decode for all six
architecture families (dense / moe / ssm / hybrid / audio / vlm).

Layer stacking: every family scans over *stacked* layer params (leaves carry a
leading L axis) to keep the HLO size O(1) in depth. Structured depth patterns are
expressed as static scan shapes, never lax.cond (exact FLOP accounting for the
roofline):

  dense/moe/audio/vlm : single scan over L blocks
  gemma2 local/global : scan over L/2 super-layers = [local(SWA) block, global block]
  ssm (mamba1)        : single scan over L mixer blocks
  hybrid (zamba2)     : scan over groups of `hybrid_attn_every` mamba2 blocks, each
                        followed by the SHARED attention+MLP block (one param set,
                        applied G times — Zamba's weight tying); tail layers in a
                        second scan

Modality frontends (audio / vlm) are STUBS per the assignment spec: the batch
carries precomputed `prefix_embeds` (B, P, d_model) which are projected and
prepended; loss is computed on token positions only.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

Array = jax.Array
PyTree = Any

CE_CHUNK = 256          # sequence chunk for the memory-bounded cross-entropy


# ===========================================================================
# init
# ===========================================================================

def _stack_init(fn, rng, n):
    return jax.vmap(fn)(jax.random.split(rng, n))


def init_params(cfg: ArchConfig, rng: jax.Array) -> Dict:
    dt = jnp.dtype(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.head_dim_
    r_embed, r_layers, r_extra = jax.random.split(rng, 3)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(r_embed, (cfg.vocab_size, d)) * d ** -0.5
                  ).astype(dt),
        "final_norm": jnp.zeros((d,), dt),
    }

    if cfg.family in ("dense", "audio", "vlm", "moe"):
        def one(r):
            r1, r2 = jax.random.split(r)
            he, kve = cfg.eff_heads
            blk = {"attn": L.attn_init(r1, d, cfg.num_heads, cfg.num_kv_heads,
                                       hd, dt, h_eff=he, kv_eff=kve)}
            if cfg.family == "moe":
                blk["moe"] = moe_lib.moe_init(r2, d, cfg.d_ff, cfg.num_experts, dt)
            else:
                blk["mlp"] = L.mlp_init(r2, d, cfg.d_ff, dt)
            return blk
        params["layers"] = _stack_init(one, r_layers, cfg.num_layers)

    elif cfg.family == "ssm":
        def one(r):
            return {"mamba": ssm_lib.mamba1_init(
                r, d, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv, dt)}
        params["layers"] = _stack_init(one, r_layers, cfg.num_layers)

    elif cfg.family == "hybrid":
        def one(r):
            return {"mamba": ssm_lib.mamba2_init(
                r, d, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim,
                cfg.ssm_conv, dt)}
        params["layers"] = _stack_init(one, r_layers, cfg.num_layers)
        r1, r2 = jax.random.split(r_extra)
        he, kve = cfg.eff_heads
        params["shared_attn"] = {
            "attn": L.attn_init(r1, d, cfg.num_heads, cfg.num_kv_heads, hd, dt,
                                h_eff=he, kv_eff=kve),
            "mlp": L.mlp_init(r2, d, cfg.d_ff, dt),
        }

    if cfg.frontend is not None:
        params["frontend_proj"] = (
            jax.random.normal(r_extra, (d, d)) * d ** -0.5).astype(dt)
    return params


# ===========================================================================
# sharding rules (tp = 'model' mesh axis)
# ===========================================================================

def param_pspecs(cfg: ArchConfig, tp: int = 16) -> Dict:
    """PartitionSpec pytree matching init_params. Megatron-style rules; dims not
    divisible by tp are replicated (noted per-arch in DESIGN.md §5)."""
    d = cfg.d_model

    def div(n):
        return n % tp == 0

    tp_ax = "model"
    emb = P(tp_ax, None) if div(cfg.vocab_size) else P(None, None)
    h_eff, kv_eff = cfg.eff_heads

    def attn_spec(stacked: bool):
        pre = (None,) if stacked else ()
        h_ok, kv_ok = div(h_eff), div(kv_eff)
        return {
            "wq": P(*pre, None, tp_ax if h_ok else None, None),
            "wk": P(*pre, None, tp_ax if kv_ok else None, None),
            "wv": P(*pre, None, tp_ax if kv_ok else None, None),
            "wo": P(*pre, tp_ax if h_ok else None, None, None),
            "norm": P(*pre, None),
        }

    def mlp_spec(stacked: bool):
        pre = (None,) if stacked else ()
        ff_ok = div(cfg.d_ff)
        return {
            "w_gate": P(*pre, None, tp_ax if ff_ok else None),
            "w_up": P(*pre, None, tp_ax if ff_ok else None),
            "w_down": P(*pre, tp_ax if ff_ok else None, None),
            "norm": P(*pre, None),
        }

    specs: Dict[str, Any] = {"embed": emb, "final_norm": P(None)}

    if cfg.family in ("dense", "audio", "vlm", "moe"):
        blk: Dict[str, Any] = {"attn": attn_spec(True)}
        if cfg.family == "moe":
            ep = div(cfg.num_experts)        # expert-parallel if possible
            ff_ok = div(cfg.d_ff)
            e_ax = tp_ax if ep else None
            f_ax = None if ep else (tp_ax if ff_ok else None)
            blk["moe"] = {
                "router": P(None, None, None),
                "w_gate": P(None, e_ax, None, f_ax),
                "w_up": P(None, e_ax, None, f_ax),
                "w_down": P(None, e_ax, f_ax, None),
                "norm": P(None, None),
            }
        else:
            blk["mlp"] = mlp_spec(True)
        specs["layers"] = blk

    elif cfg.family == "ssm":
        di_ok = div(cfg.d_inner)
        a = tp_ax if di_ok else None
        specs["layers"] = {"mamba": {
            "in_proj": P(None, None, a), "conv_w": P(None, None, a),
            "x_proj": P(None, a, None), "dt_proj": P(None, None, a),
            "dt_bias": P(None, a), "A_log": P(None, a, None), "D": P(None, a),
            "out_proj": P(None, a, None), "norm": P(None, None),
        }}

    elif cfg.family == "hybrid":
        di_ok = div(cfg.d_inner)
        nh_ok = div(cfg.d_inner // cfg.ssm_head_dim)
        a = tp_ax if di_ok else None
        h_ax = tp_ax if nh_ok else None
        specs["layers"] = {"mamba": {
            "in_x": P(None, None, a), "in_z": P(None, None, a),
            "in_B": P(None, None, None), "in_C": P(None, None, None),
            "in_dt": P(None, None, h_ax), "dt_bias": P(None, h_ax),
            "conv_w": P(None, None, None),
            "A_log": P(None, h_ax), "D": P(None, h_ax),
            "out_proj": P(None, a, None), "norm": P(None, None),
            "out_norm": P(None, a),
        }}
        specs["shared_attn"] = {"attn": attn_spec(False), "mlp": mlp_spec(False)}

    if cfg.frontend is not None:
        specs["frontend_proj"] = P(None, None)
    return specs


# ===========================================================================
# blocks
# ===========================================================================

def _dense_block(cfg: ArchConfig, blk: Dict, h: Array, positions: Array, *,
                 window, cache=None, pos=None) -> Tuple[Array, Any, Dict]:
    delta, new_cache = L.attn_apply(
        blk["attn"], h, positions, rope_theta=cfg.rope_theta, eps=cfg.norm_eps,
        chunk=cfg.attn_chunk, window=window, cap=cfg.logit_softcap,
        cache=cache, pos_scalar=pos)
    h = h + delta
    aux = {}
    if "moe" in blk:
        moe_fn = moe_lib.moe_apply_dense if cfg.moe_impl == "dense" \
            else moe_lib.moe_apply
        delta, aux = moe_fn(
            blk["moe"], h, k=cfg.num_experts_per_tok,
            cf=cfg.moe_capacity_factor, eps=cfg.norm_eps)
    else:
        delta = L.mlp_apply(blk["mlp"], h, cfg.norm_eps)
    return h + delta, new_cache, aux


def _mamba_block(cfg: ArchConfig, blk: Dict, h: Array, *, states=None):
    fn = ssm_lib.mamba1_apply if cfg.ssm_variant == "mamba1" \
        else ssm_lib.mamba2_apply
    ssm_state, conv_state = states if states is not None else (None, None)
    delta, new_states = fn(blk["mamba"], h, cfg,
                           ssm_state=ssm_state, conv_state=conv_state)
    return h + delta, new_states


def _zero_aux():
    return {"load_balance": jnp.zeros(()), "router_z": jnp.zeros(()),
            "dropped_frac": jnp.zeros(())}


# ===========================================================================
# forward stacks (train / prefill path: full-sequence, cache optional)
# ===========================================================================

def _run_stack(cfg: ArchConfig, params: Dict, h: Array, positions: Array,
               cache: Optional[Dict], train: bool) -> Tuple[Array, Dict, Dict]:
    """Full-sequence pass. Returns (h, new_cache, aux)."""
    new_cache: Dict[str, Any] = {}
    aux = _zero_aux()

    if cfg.family in ("dense", "audio", "vlm", "moe") and not cfg.local_global:
        window = cfg.sliding_window

        has_cache = cache is not None

        def body(carry, xs):
            h, aux = carry
            blk, cache_l = xs
            h, nc, a = _dense_block(cfg, blk, h, positions, window=window,
                                    cache=cache_l if has_cache else None)
            for k in a:
                aux[k] = aux[k] + a[k]
            return (h, aux), nc
        if train and cfg.remat:
            body = jax.checkpoint(body)
        cache_kv = None if cache is None else (cache["k"], cache["v"])
        xs = (params["layers"],
              cache_kv if cache is not None else
              (jnp.zeros((cfg.num_layers, 0)), jnp.zeros((cfg.num_layers, 0))))
        (h, aux), nc = jax.lax.scan(body, (h, aux), xs)
        if cache is not None:
            new_cache = {"k": nc[0], "v": nc[1]}

    elif cfg.local_global:   # gemma2: [local, global] super-layers
        has_cache = cache is not None

        def body(carry, xs):
            h, aux = carry
            pair, c_loc, c_glob = xs
            loc = jax.tree_util.tree_map(lambda t: t[0], pair)
            glb = jax.tree_util.tree_map(lambda t: t[1], pair)
            h, nc_l, _ = _dense_block(cfg, loc, h, positions,
                                      window=cfg.sliding_window,
                                      cache=c_loc if has_cache else None)
            h, nc_g, _ = _dense_block(cfg, glb, h, positions, window=None,
                                      cache=c_glob if has_cache else None)
            return (h, aux), (nc_l, nc_g)
        if train and cfg.remat:
            body = jax.checkpoint(body)
        n2 = cfg.num_layers // 2
        pairs = jax.tree_util.tree_map(
            lambda t: t.reshape(n2, 2, *t.shape[1:]), params["layers"])
        if cache is not None:
            xs = (pairs, (cache["k_local"], cache["v_local"]),
                  (cache["k_global"], cache["v_global"]))
        else:
            z = (jnp.zeros((n2, 0)), jnp.zeros((n2, 0)))
            xs = (pairs, z, z)
        (h, aux), (nc_l, nc_g) = jax.lax.scan(body, (h, aux), xs)
        if cache is not None:
            new_cache = {"k_local": nc_l[0], "v_local": nc_l[1],
                         "k_global": nc_g[0], "v_global": nc_g[1]}

    elif cfg.family == "ssm":
        def body(carry, xs):
            h, aux = carry
            blk, st = xs
            h, ns = _mamba_block(cfg, blk, h, states=st if cache is not None
                                 else None)
            return (h, aux), ns
        if train and cfg.remat:
            body = jax.checkpoint(body)
        sts = (cache["ssm"], cache["conv"]) if cache is not None else \
            (jnp.zeros((cfg.num_layers, 0)), jnp.zeros((cfg.num_layers, 0)))
        (h, aux), ns = jax.lax.scan(body, (h, aux), (params["layers"], sts))
        if cache is not None:
            new_cache = {"ssm": ns[0], "conv": ns[1]}

    elif cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        G = cfg.num_layers // k_every
        tail = cfg.num_layers - G * k_every
        shared = params["shared_attn"]
        attn_window = cfg.sliding_window

        def mamba_scan(h, blocks, sts):
            def body(carry, xs):
                hh, _ = carry
                blk, st = xs
                hh, ns = _mamba_block(cfg, blk, hh,
                                      states=st if cache is not None else None)
                return (hh, 0), ns
            if train and cfg.remat:
                body = jax.checkpoint(body)
            (h, _), ns = jax.lax.scan(body, (h, 0), (blocks, sts))
            return h, ns

        has_cache = cache is not None

        def group_body(carry, xs):
            h, aux = carry
            blocks, sts, c_attn = xs
            h, ns = mamba_scan(h, blocks, sts)
            d1, nc = L.attn_apply(shared["attn"], h, positions,
                                  rope_theta=cfg.rope_theta, eps=cfg.norm_eps,
                                  chunk=cfg.attn_chunk, window=attn_window,
                                  cap=cfg.logit_softcap,
                                  cache=c_attn if has_cache else None)
            h = h + d1
            h = h + L.mlp_apply(shared["mlp"], h, cfg.norm_eps)
            return (h, aux), (ns, nc)

        def slice_tree(tree, a, b):
            return jax.tree_util.tree_map(lambda t: t[a:b], tree)

        main = jax.tree_util.tree_map(
            lambda t: t[: G * k_every].reshape(G, k_every, *t.shape[1:]),
            params["layers"])
        if cache is not None:
            sts_main = jax.tree_util.tree_map(
                lambda t: t[: G * k_every].reshape(G, k_every, *t.shape[1:]),
                (cache["ssm"], cache["conv"]))
            c_attn = (cache["k_attn"], cache["v_attn"])
        else:
            sts_main = (jnp.zeros((G, k_every, 0)), jnp.zeros((G, k_every, 0)))
            c_attn = (jnp.zeros((G, 0)), jnp.zeros((G, 0)))
        (h, aux), (ns_main, nc_attn) = jax.lax.scan(
            group_body, (h, aux), (main, sts_main, c_attn))

        ns_tail = None
        if tail:
            tail_blocks = slice_tree(params["layers"], G * k_every,
                                     cfg.num_layers)
            sts_tail = (slice_tree(cache["ssm"], G * k_every, cfg.num_layers),
                        slice_tree(cache["conv"], G * k_every, cfg.num_layers)) \
                if cache is not None else (jnp.zeros((tail, 0)),
                                           jnp.zeros((tail, 0)))
            h, ns_tail = mamba_scan(h, tail_blocks, sts_tail)

        if cache is not None:
            def unsplit(main_t, tail_t):
                m = main_t.reshape(G * k_every, *main_t.shape[2:])
                return jnp.concatenate([m, tail_t], 0) if tail else m
            new_cache = {
                "ssm": unsplit(ns_main[0], ns_tail[0] if tail else None),
                "conv": unsplit(ns_main[1], ns_tail[1] if tail else None),
                "k_attn": nc_attn[0], "v_attn": nc_attn[1],
            }
    else:
        raise ValueError(cfg.family)

    return h, new_cache, aux


# ===========================================================================
# decode stacks (single token, cache required)
# ===========================================================================

def _decode_stack(cfg: ArchConfig, params: Dict, h: Array, pos: Array,
                  cache: Dict) -> Tuple[Array, Dict]:
    positions = pos[None, None] if pos.ndim == 0 else pos[:, None]
    positions = jnp.broadcast_to(positions, (h.shape[0], 1))

    if cfg.family in ("dense", "audio", "vlm", "moe") and not cfg.local_global:
        def body(h, xs):
            blk, (kc, vc) = xs
            hh, nc, _ = _dense_block(cfg, blk, h, positions,
                                     window=cfg.sliding_window,
                                     cache=(kc, vc), pos=pos)
            return hh, nc
        h, nc = jax.lax.scan(body, h, (params["layers"],
                                       (cache["k"], cache["v"])))
        return h, {"k": nc[0], "v": nc[1]}

    if cfg.local_global:
        n2 = cfg.num_layers // 2
        pairs = jax.tree_util.tree_map(
            lambda t: t.reshape(n2, 2, *t.shape[1:]), params["layers"])

        def body(h, xs):
            pair, (klc, vlc), (kgc, vgc) = xs
            loc = jax.tree_util.tree_map(lambda t: t[0], pair)
            glb = jax.tree_util.tree_map(lambda t: t[1], pair)
            h, nc_l, _ = _dense_block(cfg, loc, h, positions,
                                      window=cfg.sliding_window,
                                      cache=(klc, vlc), pos=pos)
            h, nc_g, _ = _dense_block(cfg, glb, h, positions, window=None,
                                      cache=(kgc, vgc), pos=pos)
            return h, (nc_l, nc_g)
        h, (nc_l, nc_g) = jax.lax.scan(
            body, h, (pairs, (cache["k_local"], cache["v_local"]),
                      (cache["k_global"], cache["v_global"])))
        return h, {"k_local": nc_l[0], "v_local": nc_l[1],
                   "k_global": nc_g[0], "v_global": nc_g[1]}

    if cfg.family == "ssm":
        def body(h, xs):
            blk, st = xs
            h, ns = _mamba_block(cfg, blk, h, states=st)
            return h, ns
        h, ns = jax.lax.scan(body, h, (params["layers"],
                                       (cache["ssm"], cache["conv"])))
        return h, {"ssm": ns[0], "conv": ns[1]}

    if cfg.family == "hybrid":
        k_every = cfg.hybrid_attn_every
        G = cfg.num_layers // k_every
        tail = cfg.num_layers - G * k_every
        shared = params["shared_attn"]
        window = cfg.sliding_window

        def mamba_scan(h, blocks, sts):
            def body(h, xs):
                blk, st = xs
                h, ns = _mamba_block(cfg, blk, h, states=st)
                return h, ns
            return jax.lax.scan(body, h, (blocks, sts))

        def group_body(h, xs):
            blocks, sts, (kc, vc) = xs
            h, ns = mamba_scan(h, blocks, sts)
            d1, nc = L.attn_apply(shared["attn"], h, positions,
                                  rope_theta=cfg.rope_theta, eps=cfg.norm_eps,
                                  chunk=cfg.attn_chunk, window=window,
                                  cap=cfg.logit_softcap, cache=(kc, vc),
                                  pos_scalar=pos)
            h = h + d1
            h = h + L.mlp_apply(shared["mlp"], h, cfg.norm_eps)
            return h, (ns, nc)

        main = jax.tree_util.tree_map(
            lambda t: t[: G * k_every].reshape(G, k_every, *t.shape[1:]),
            params["layers"])
        sts_main = jax.tree_util.tree_map(
            lambda t: t[: G * k_every].reshape(G, k_every, *t.shape[1:]),
            (cache["ssm"], cache["conv"]))
        h, (ns_main, nc_attn) = jax.lax.scan(
            group_body, h, (main, sts_main, (cache["k_attn"], cache["v_attn"])))
        new_cache = {"k_attn": nc_attn[0], "v_attn": nc_attn[1]}
        ns_tail = None
        if tail:
            tail_blocks = jax.tree_util.tree_map(
                lambda t: t[G * k_every:], params["layers"])
            sts_tail = (cache["ssm"][G * k_every:], cache["conv"][G * k_every:])
            h, ns_tail = mamba_scan(h, tail_blocks, sts_tail)

        def unsplit(main_t, tail_t):
            m = main_t.reshape(G * k_every, *main_t.shape[2:])
            return jnp.concatenate([m, tail_t], 0) if tail else m
        new_cache["ssm"] = unsplit(ns_main[0], ns_tail[0] if tail else None)
        new_cache["conv"] = unsplit(ns_main[1], ns_tail[1] if tail else None)
        return h, new_cache

    raise ValueError(cfg.family)


# ===========================================================================
# public entry points
# ===========================================================================

def _embed(cfg: ArchConfig, params: Dict, tokens: Array,
           prefix_embeds: Optional[Array]) -> Tuple[Array, int]:
    adt = cfg.activation_dtype
    h = jnp.take(params["embed"], tokens, axis=0).astype(adt)
    if cfg.name.startswith("gemma"):
        h = h * jnp.asarray(cfg.d_model ** 0.5, adt)
    n_prefix = 0
    if prefix_embeds is not None:
        pe = jnp.einsum("bpd,de->bpe", prefix_embeds.astype(adt),
                        params["frontend_proj"].astype(adt))
        h = jnp.concatenate([pe, h], axis=1)
        n_prefix = prefix_embeds.shape[1]
    return h, n_prefix


def _logits(cfg: ArchConfig, params: Dict, h: Array) -> Array:
    lg = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    return L.softcap(lg.astype(jnp.float32), cfg.final_softcap)


def train_loss(cfg: ArchConfig, params: Dict, batch: Dict
               ) -> Tuple[Array, Dict]:
    """batch: tokens (B,S), labels (B,S), optional prefix_embeds (B,P,d)."""
    tokens, labels = batch["tokens"], batch["labels"]
    h, n_prefix = _embed(cfg, params, tokens, batch.get("prefix_embeds"))
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])
    h, _, aux = _run_stack(cfg, params, h, positions, cache=None, train=True)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    h = h[:, n_prefix:]

    # chunked cross-entropy (never materialize (B,S,V) in full)
    B, S, d = h.shape
    cs = min(CE_CHUNK, S)
    ncs = -(-S // cs)
    pad = ncs * cs - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0))).reshape(B, ncs, cs, d)
    lp = jnp.pad(labels, ((0, 0), (0, pad))).reshape(B, ncs, cs)
    mp = jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad))).reshape(B, ncs, cs)

    def ce_chunk(carry, xs):
        hc, lc, mc = xs                                 # (B,cs,d),(B,cs),(B,cs)
        lg = _logits(cfg, params, hc)                   # (B,cs,V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        ce = jnp.where(mc, lse - gold, 0.0)
        return carry + ce.sum(), None

    total, _ = jax.lax.scan(
        ce_chunk, jnp.zeros((), jnp.float32),
        (hp.swapaxes(0, 1), lp.swapaxes(0, 1), mp.swapaxes(0, 1)))
    loss = total / (B * S)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux["load_balance"] + 0.001 * aux["router_z"]
    return loss, aux


def init_cache(cfg: ArchConfig, batch_size: int, max_seq: int,
               dtype=jnp.bfloat16) -> Dict:
    hd, KV, Ltot = cfg.head_dim_, cfg.num_kv_heads, cfg.num_layers
    if cfg.family in ("dense", "audio", "vlm", "moe") and not cfg.local_global:
        S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        z = jnp.zeros((Ltot, batch_size, S, KV, hd), dtype)
        return {"k": z, "v": z}
    if cfg.local_global:
        n2 = Ltot // 2
        Sl = min(max_seq, cfg.sliding_window)
        zl = jnp.zeros((n2, batch_size, Sl, KV, hd), dtype)
        zg = jnp.zeros((n2, batch_size, max_seq, KV, hd), dtype)
        return {"k_local": zl, "v_local": zl, "k_global": zg, "v_global": zg}
    if cfg.family == "ssm":
        return {
            "ssm": jnp.zeros((Ltot, batch_size, cfg.d_inner, cfg.ssm_state),
                             jnp.float32),
            "conv": jnp.zeros((Ltot, batch_size, cfg.ssm_conv - 1, cfg.d_inner),
                              dtype),
        }
    if cfg.family == "hybrid":
        G = Ltot // cfg.hybrid_attn_every
        nh = cfg.d_inner // cfg.ssm_head_dim
        Sa = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        za = jnp.zeros((G, batch_size, Sa, KV, hd), dtype)
        return {
            "ssm": jnp.zeros((Ltot, batch_size, nh, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((Ltot, batch_size, cfg.ssm_conv - 1,
                               cfg.d_inner + 2 * cfg.ssm_state), dtype),
            "k_attn": za, "v_attn": za,
        }
    raise ValueError(cfg.family)


def prefill(cfg: ArchConfig, params: Dict, batch: Dict, cache: Dict
            ) -> Tuple[Array, Dict]:
    """Process the full prompt; returns (last-token logits, filled cache).

    ``batch["prompt_lens"]`` (optional, (B,) int32 true lengths) selects each
    row's logits at its last REAL token, ``n_prefix + len − 1``, instead of
    the rightmost column — right-padded rows otherwise read logits computed
    on pad tokens, and pad id 0 is a legal vocab token. Causal attention
    makes the gathered position's activations independent of the padding to
    its right, so the first generated token is exact."""
    tokens = batch["tokens"]
    h, n_prefix = _embed(cfg, params, tokens, batch.get("prefix_embeds"))
    positions = jnp.broadcast_to(jnp.arange(h.shape[1])[None], h.shape[:2])
    h, new_cache, _ = _run_stack(cfg, params, h, positions, cache=cache,
                                 train=False)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    lens = batch.get("prompt_lens")
    if lens is None:
        h_last = h[:, -1:]
    else:
        idx = n_prefix + lens.astype(jnp.int32) - 1          # (B,)
        h_last = h[jnp.arange(h.shape[0])[:, None], idx[:, None]]  # (B,1,d)
    return _logits(cfg, params, h_last), new_cache


def decode_step(cfg: ArchConfig, params: Dict, cache: Dict, tokens: Array,
                pos: Array) -> Tuple[Array, Dict]:
    """One decode step. tokens: (B,1) int32; pos: scalar absolute position."""
    h, _ = _embed(cfg, params, tokens, None)
    h, new_cache = _decode_stack(cfg, params, h, pos, cache)
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, h), new_cache
